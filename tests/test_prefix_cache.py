"""Cross-request shared-prefix cache: content-addressable admission,
refcounts, copy-on-write, billing, and scheduler integration (ISSUE 7).

The load-bearing properties:

* a warm-prefix admission is TOKEN-IDENTICAL to a cold admission, under
  randomized interleavings of admissions, decodes and releases;
* refcounts never strand or double-free a chunk — releasing N sharers
  leaves the arena bytes exactly as the single-owner state, and entries
  survive as warm cache until arena pressure evicts them;
* COW privatizes the writer's chunk while still-shared readers keep
  their bytes bit-for-bit;
* by-reference adoption bills ZERO transfer bytes (``prefix_ref`` ops)
  and COW bills exactly one chunk copy per layer (``cow_copy``).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
from repro.serving.prefix import PrefixIndex, chunk_hashes
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg
from repro.serving.simulator import (HWCfg, ServeCfg, prefill_time,
                                     prefill_time_prefix)

CHUNK = 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=CHUNK,
                                       importance_rate=0.4, early_rate=0.6,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _engine(cfg, params, prefix_cache=True, max_seqs=3, **kw):
    ecfg = EngineCfg(max_len=128, selection="tree",
                     prefill_chunk_tokens=32, prefix_cache=prefix_cache,
                     **kw)
    return BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=max_seqs)


def _decode(eng, sid, tok, n):
    stream = [tok]
    cur = {sid: tok}
    for _ in range(n):
        cur = eng.decode_round(cur)
        stream.append(cur[sid])
    return stream


# ---------------------------------------------------------------------------
# chunk_hashes / PrefixIndex units
# ---------------------------------------------------------------------------


def test_chunk_hashes_chain_commits_to_prefix():
    rng = np.random.RandomState(0)
    toks = rng.randint(2, 500, 64)
    h = chunk_hashes(toks, CHUNK)
    assert len(h) == 4
    # same prefix -> same hashes; a change in chunk 1 changes chunks 1..3
    other = toks.copy()
    other[CHUNK] += 1
    h2 = chunk_hashes(other, CHUNK)
    assert h2[0] == h[0] and all(a != b for a, b in zip(h[1:], h2[1:]))
    # the partial tail commits to its length: 26 tokens vs the 32-token
    # extension disagree on chunk 1 even though the 26 tokens are shared
    assert chunk_hashes(toks[:26], CHUNK)[1] != chunk_hashes(
        toks[:32], CHUNK)[1]
    # chunk granularity changes the chain entirely
    assert chunk_hashes(toks, CHUNK)[0] != chunk_hashes(toks, 2 * CHUNK)[0]


def test_prefix_index_match_refcounts_and_eviction():
    px = PrefixIndex(rows=[10, 11])
    h = [b"h%d" % i for i in range(3)]
    row, scrub = px.alloc_row()
    assert (row, scrub) == (10, [])
    px.plan(row, range(3))
    for c in range(3):
        assert px.publish(row, c, h[c])
    assert not px.publish(99, 0, h[0])        # first registrant wins
    assert px.match(h) == [(10, 0), (10, 1), (10, 2)]
    assert px.match([h[0], b"x", h[2]]) == [(10, 0)]  # stops at first miss
    px.acquire([(10, 0)])
    px.acquire([(10, 0)])
    assert px.ref_count((10, 0)) == 2
    px.decref([(10, 0)])
    assert px.ref_count((10, 0)) == 1
    # a pinned row is not evictable: second alloc takes the free row,
    # third finds nothing
    row2, _ = px.alloc_row()
    assert row2 == 11
    px.plan(row2, [0])
    px.acquire([(row2, 0)])
    assert px.alloc_row() is None
    # dropping the last refs makes row 10 LRU-evictable; its entries go
    px.decref([(10, 0)])
    victim, scrub = px.alloc_row()
    assert victim == 10 and scrub == [0, 1, 2]
    assert px.match(h, record=False) == []
    with pytest.raises(AssertionError):
        px.decref([(10, 0)])                  # double-free trips


# ---------------------------------------------------------------------------
# warm == cold token identity under randomized interleavings
# ---------------------------------------------------------------------------


def _schedule(seed, n_admit=6, max_live=3):
    """Deterministic event list: admit/decode/release with shared
    prefixes and chunk-partial suffixes (so COW paths fire)."""
    rng = np.random.RandomState(seed)
    prefixes = [rng.randint(2, 500, 64) for _ in range(2)]
    events, live, decoded, admitted = [], [], {}, 0
    while admitted < n_admit or live:
        roll = rng.rand()
        if admitted < n_admit and len(live) < max_live and roll < 0.4:
            p = np.concatenate([prefixes[rng.randint(2)],
                                rng.randint(2, 500, rng.choice([8, 12, 16]))])
            events.append(("admit", admitted, p))
            live.append(admitted)
            decoded[admitted] = 0
            admitted += 1
        elif live and roll < 0.75:
            events.append(("decode",))
            for r in live:
                decoded[r] += 1
        elif live:
            full = [r for r in live if decoded[r] >= 5]
            r = full[0] if full else live[rng.randint(len(live))]
            events.append(("release", r))
            live.remove(r)
        for r in [r for r in live if decoded[r] >= 6]:
            events.append(("release", r))
            live.remove(r)
    return events


def _replay(cfg, params, events, prefix_cache):
    eng = _engine(cfg, params, prefix_cache=prefix_cache)
    streams, sids, cur = {}, {}, {}
    for ev in events:
        if ev[0] == "admit":
            _, rid, prompt = ev
            sid, tok = eng.add_sequence(prompt)
            sids[rid], streams[rid], cur[sid] = sid, [tok], tok
        elif ev[0] == "decode":
            cur = eng.decode_round(cur)
            for rid, sid in sids.items():
                if sid in cur:
                    streams[rid].append(cur[sid])
        else:
            sid = sids[ev[1]]
            eng.release(sid)
            cur.pop(sid, None)
    stats = eng.store.prefix_stats()
    eng.store.close()
    return streams, stats


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_warm_admission_token_identical_to_cold(setup, seed):
    """Property (randomized over seeds): any interleaving of admissions,
    decode rounds and releases over shared prefixes decodes the same
    token streams with the cache on and off."""
    cfg, params = setup
    events = _schedule(seed)
    warm, stats = _replay(cfg, params, events, prefix_cache=True)
    cold, _ = _replay(cfg, params, events, prefix_cache=False)
    assert warm == cold, (seed, warm, cold)
    # the schedule shares prefixes across admissions: reuse must engage
    assert stats["prefix_hit_chunks"] > 0
    assert stats["shared_refs"] == 0          # all released -> no strand


# ---------------------------------------------------------------------------
# refcounts: N sharers release -> single-owner state, no strand/double-free
# ---------------------------------------------------------------------------


def test_release_of_n_sharers_restores_single_owner_state(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=3)
    rng = np.random.RandomState(5)
    prompt = rng.randint(2, cfg.vocab_size, 80)   # 5 full chunks
    store = eng.store

    # single owner: registrant only, snapshot its arena refs
    sid0, tok0 = eng.add_sequence(prompt)
    single_refs = dict(store._prefix.refs)
    arena_disk = {row: np.array(store._disk[row])
                  for m in store._shared_map.values() for row in set(m.values())}
    streams = {sid0: _decode(eng, sid0, tok0, 2)}

    # two more sharers join, then release in admission order
    sid1, tok1 = eng.add_sequence(prompt)
    sid2, tok2 = eng.add_sequence(prompt)
    streams[sid1] = _decode(eng, sid1, tok1, 2)
    streams[sid2] = _decode(eng, sid2, tok2, 2)
    assert streams[sid1] == streams[sid0] == streams[sid2]
    assert store._prefix.live_refs() > sum(single_refs.values())
    eng.release(sid1)
    eng.release(sid2)

    # bytes AND refcounts are back to the single-owner state; the arena
    # payload never moved
    assert dict(store._prefix.refs) == single_refs
    for row, snap in arena_disk.items():
        np.testing.assert_array_equal(np.array(store._disk[row]), snap)
    eng.release(sid0)
    assert store._prefix.live_refs() == 0     # nothing stranded
    # zero refs is CACHE, not garbage: a fresh admission is still warm
    sid3, tok3 = eng.add_sequence(prompt)
    assert store.prefix_stats()["warm_admissions"] >= 3
    assert _decode(eng, sid3, tok3, 2) == streams[sid0]
    eng.store.close()


# ---------------------------------------------------------------------------
# COW: writer privatizes, readers keep bytes bit-for-bit
# ---------------------------------------------------------------------------


def test_cow_preserves_shared_readers_bytes(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=3)
    rng = np.random.RandomState(6)
    prompt = rng.randint(2, cfg.vocab_size, 76)   # partial tail chunk
    tail_c = 76 // CHUNK                          # chunk 4, 12 tokens
    store = eng.store

    sid0, tok0 = eng.add_sequence(prompt)
    sid1, tok1 = eng.add_sequence(prompt)
    row = store._shared_map[sid1][tail_c]
    assert row >= store.n_seqs
    snap = np.array(store._disk[row, :, tail_c])

    # sid0's first append COWs its tail; sid1 still points at the arena
    s0 = _decode(eng, sid0, tok0, 3)
    assert store.cow_copies >= 1
    assert tail_c not in store._shared_map.get(sid0, {})
    assert store._shared_map[sid1][tail_c] == row
    np.testing.assert_array_equal(np.array(store._disk[row, :, tail_c]),
                                  snap)

    # the surviving reader decodes on the untouched arena bytes and
    # matches the writer's stream (identical prompts, same model)
    s1 = _decode(eng, sid1, tok1, 3)
    assert s1 == s0
    np.testing.assert_array_equal(np.array(store._disk[row, :, tail_c]),
                                  snap)
    eng.store.close()


# ---------------------------------------------------------------------------
# billing: zero-byte adoption, exactly one chunk copy per COW
# ---------------------------------------------------------------------------


def test_prefix_ref_bills_zero_and_cow_bills_one_chunk_copy(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=2)
    rng = np.random.RandomState(7)
    prompt = rng.randint(2, cfg.vocab_size, 76)
    store = eng.store
    n_layers = store.n_layers

    sid0, tok0 = eng.add_sequence(prompt)
    sid1, tok1 = eng.add_sequence(prompt)
    adopted = len(store._shared_map[sid1])
    assert adopted == 5                           # 4 full + the tail
    assert store.log.ops[("host", "disk", "prefix_ref")] == adopted
    assert store.log.bytes[("host", "disk", "prefix_ref")] == 0.0

    # warm admission wrote NO disk replicas or abstracts of its own
    replica = store.log.bytes[("host", "disk", "kv_replica")]
    _decode(eng, sid0, tok0, 2)
    _decode(eng, sid1, tok1, 2)
    cow = store.cow_copies
    assert cow >= 1
    assert store.log.bytes[("host", "disk", "cow_copy")] == \
        pytest.approx(cow * n_layers * float(store.chunk_bytes))
    assert store.log.bytes[("disk", "host", "cow_read")] == \
        pytest.approx(cow * n_layers * float(store.chunk_bytes))
    # shared-log == sum of per-seq logs still holds with the new kinds
    for key, v in store.log.bytes.items():
        per_seq = sum(lg.bytes.get(key, 0.0)
                      for lg in store.seq_logs.values())
        assert abs(v - per_seq) < 1e-6, (key, v, per_seq)
    assert replica == store.log.bytes[("host", "disk", "kv_replica")] \
        or cow > 0  # only COW may add post-admission replica traffic
    eng.store.close()


def test_shared_chunks_occupy_one_pool_slot(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=2)
    rng = np.random.RandomState(8)
    prompt = rng.randint(2, cfg.vocab_size, 80)
    store = eng.store
    sid0, tok0 = eng.add_sequence(prompt)
    sid1, tok1 = eng.add_sequence(prompt)
    cur = {sid0: tok0, sid1: tok1}
    for _ in range(2):
        cur = eng.decode_round(cur)
    # device pool slots for shared chunks are keyed by the ARENA row:
    # neither sequence ever buys a private slot for an adopted chunk
    for sid in (sid0, sid1):
        mapping = store._shared_map.get(sid, {})
        for li, pool in enumerate(store.pools):
            if pool is None:
                continue
            for c in mapping:
                assert (sid, c) not in pool.slot_of, (sid, li, c)
    eng.store.close()


# ---------------------------------------------------------------------------
# arena eviction under pressure
# ---------------------------------------------------------------------------


def test_arena_eviction_under_pressure_stays_correct(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=2, prefix_arena_rows=1)
    rng = np.random.RandomState(9)
    pa = rng.randint(2, cfg.vocab_size, 80)
    pb = rng.randint(2, cfg.vocab_size, 80)
    store = eng.store

    sid, tok = eng.add_sequence(pa)
    sa = _decode(eng, sid, tok, 2)
    # while A is live its row is pinned: B admits fully cold, unregistered
    sidb, tokb = eng.add_sequence(pb)
    assert sidb not in store._shared_map
    _decode(eng, sidb, tokb, 2)
    eng.release(sid)
    eng.release(sidb)

    # with A released, B's re-admission evicts A's row and registers
    sidb, tokb = eng.add_sequence(pb)
    assert store.prefix_stats()["arena_evictions"] == 1
    _decode(eng, sidb, tokb, 2)
    eng.release(sidb)

    # A lost its entries -> cold again, but still token-identical
    assert store.prefix_probe(pa)["hit_chunks"] == 0
    sid, tok = eng.add_sequence(pa)
    assert _decode(eng, sid, tok, 2) == sa
    eng.store.close()


# ---------------------------------------------------------------------------
# scheduler: stats surface + admission credit
# ---------------------------------------------------------------------------


def test_scheduler_stats_and_admission_credit(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=3)
    rng = np.random.RandomState(10)
    prompt = rng.randint(2, cfg.vocab_size, 80)
    b = ContinuousBatcher(cfg=SchedulerCfg(max_active=2, chunk=CHUNK),
                          engine=eng)
    req = Request(0, prompt, max_new=4)
    cold_need = b._need(req)

    # make the prefix device-resident, then a NEW rid gets the credit
    sid, tok = eng.add_sequence(prompt)
    _decode(eng, sid, tok, 2)
    eng.release(sid)
    probe = eng.store.prefix_probe(prompt)
    assert probe["device_hits"] > 0
    warm_need = b._need(Request(1, prompt, max_new=4))
    assert warm_need == max(cold_need - probe["device_hits"], 1)
    assert warm_need < cold_need
    # credit is frozen per rid (memoized): index churn can't flap it
    assert b._need(Request(1, prompt, max_new=4)) == warm_need

    sid, tok = eng.add_sequence(prompt)       # a warm admission
    eng.release(sid)
    stt = b.stats()
    assert stt["prefix_hit_rate"] > 0
    assert "shared_chunks" in stt and "bytes_deduped" in stt
    eng.store.close()


def test_scheduler_runs_requests_through_prefix_engine(setup):
    cfg, params = setup
    eng = _engine(cfg, params, max_seqs=3)
    rng = np.random.RandomState(11)
    prefix = rng.randint(2, cfg.vocab_size, 64)
    b = ContinuousBatcher(cfg=SchedulerCfg(max_active=2, chunk=CHUNK),
                          engine=eng)
    for rid in range(4):
        p = np.concatenate([prefix, rng.randint(2, cfg.vocab_size, 12)])
        b.submit(Request(rid, p, max_new=4))
    done = b.run()
    assert len(done) == 4 and all(len(r.out) == 4 for r in done)
    assert b.stats()["warm_admissions"] >= 3
    eng.store.close()


# ---------------------------------------------------------------------------
# engine config gates
# ---------------------------------------------------------------------------


def test_prefix_cache_rejects_recurrent_and_bad_chunking(setup):
    cfg, params = setup
    xcfg = get_config("xlstm-125m", smoke=True)
    xparams = lm.init(xcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        BatchedLeoAMEngine(xcfg, xparams,
                           EngineCfg(max_len=128, prefix_cache=True,
                                     prefill_chunk_tokens=32))
    with pytest.raises(ValueError, match="multiple"):
        BatchedLeoAMEngine(cfg, params,
                           EngineCfg(max_len=128, prefix_cache=True,
                                     prefill_chunk_tokens=24))


# ---------------------------------------------------------------------------
# simulator: prefix-aware TTFT model
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.floats(0.0, 1.0))
def test_prefill_time_prefix_bounded_and_anchored(hit_frac):
    cfg = get_config("longchat-7b-32k")
    scfg, hw = ServeCfg(), HWCfg()
    base = prefill_time(cfg, scfg, hw)
    t = prefill_time_prefix(cfg, scfg, hw, hit_frac)
    assert 0.0 < t <= base + 1e-12
    assert prefill_time_prefix(cfg, scfg, hw, 0.0) == pytest.approx(base)


def test_prefill_time_prefix_monotone_decreasing():
    cfg = get_config("longchat-7b-32k")
    scfg, hw = ServeCfg(), HWCfg()
    ts = [prefill_time_prefix(cfg, scfg, hw, h)
          for h in np.linspace(0.0, 1.0, 9)]
    assert all(a > b for a, b in zip(ts, ts[1:]))
