"""Runtime sync-sanitizer: wrong-thread detection, the concurrent-mutation
(epoch) guard, lock-order cycle tracking, and the schedule-fuzz stress run
of the full debug_sync engine."""

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import sanitizer
from repro.serving.offload import TieredKVStore
from repro.serving.sanitizer import (LockOrderTracker, SyncViolation,
                                     TrackedLock, decode_thread_only)


# ----------------------------------------------------------------------
# wrong-thread detection
# ----------------------------------------------------------------------
def test_wrong_thread_store_mutation_trips_sanitizer():
    """A decode-thread-only store method submitted to a leoam-* executor
    raises instead of racing the decode thread."""
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None,
                        debug_sync=True)
    try:
        assert sanitizer.active()
        st_.clear_seq(0)                      # decode thread: fine
        ex = ThreadPoolExecutor(1, thread_name_prefix="leoam-test")
        with pytest.raises(SyncViolation, match="decode-thread-only"):
            ex.submit(st_.clear_seq, 0).result()
        ex.shutdown()
    finally:
        st_.close()


def test_registered_worker_thread_double_trips_sanitizer():
    """register_worker_thread() makes an anonymous test-double thread a
    worker for the sanitizer even without the leoam- name."""

    class Pool:
        @decode_thread_only
        def scatter(self, slots):
            return slots

    pool = Pool()
    sanitizer.enable()
    errs = []
    try:
        def run():
            sanitizer.register_worker_thread()
            try:
                pool.scatter([0])
            except SyncViolation as e:
                errs.append(e)

        t = threading.Thread(target=run)
        t.start()
        t.join()
    finally:
        sanitizer.disable()
    assert len(errs) == 1 and "scatter" in str(errs[0])


def test_sanitizer_off_is_free():
    """With the sanitizer disabled the decorator is pass-through even on a
    worker-named thread."""

    class Pool:
        @decode_thread_only
        def scatter(self, slots):
            return list(slots)

    pool = Pool()
    assert not sanitizer.active()
    ex = ThreadPoolExecutor(1, thread_name_prefix="leoam-test")
    assert ex.submit(pool.scatter, (1, 2)).result() == [1, 2]
    ex.shutdown()


# ----------------------------------------------------------------------
# concurrent-mutation (epoch) guard
# ----------------------------------------------------------------------
def test_epoch_guard_rejects_interleaved_mutators():
    """Two non-worker threads interleaving inside one decode-thread-only
    mutator of the same object is a hard error, not silent corruption."""

    class Slab:
        def __init__(self):
            self.inside = threading.Event()
            self.release = threading.Event()

        @decode_thread_only
        def fold(self):
            self.inside.set()
            self.release.wait(5.0)

    slab = Slab()
    sanitizer.enable()
    try:
        t = threading.Thread(target=slab.fold, name="imposter-decode")
        t.start()
        assert slab.inside.wait(5.0)
        with pytest.raises(SyncViolation, match="concurrent mutation"):
            slab.fold()
        slab.release.set()
        t.join()
        slab.fold()                           # guard resets after exit
    finally:
        sanitizer.disable()


def test_epoch_guard_allows_reentrancy():
    class Slab:
        @decode_thread_only
        def outer(self):
            return self.inner() + 1

        @decode_thread_only
        def inner(self):
            return 1

    sanitizer.enable()
    try:
        assert Slab().outer() == 2
    finally:
        sanitizer.disable()


# ----------------------------------------------------------------------
# lock-order tracker
# ----------------------------------------------------------------------
def test_lock_order_cycle_raises():
    tr = LockOrderTracker()
    la = TrackedLock(threading.Lock(), "A", tr)
    lb = TrackedLock(threading.Lock(), "B", tr)
    with la:
        with lb:
            assert sanitizer.held_locks() == ("A", "B")
    assert sanitizer.held_locks() == ()
    with lb:
        with pytest.raises(SyncViolation, match="lock-order cycle"):
            la.acquire()
    assert tr.edges()["A"] == {"B"}
    assert "A" not in tr.edges().get("B", set())   # cycle edge NOT recorded


def test_lock_order_consistent_nesting_is_fine():
    tr = LockOrderTracker()
    la = TrackedLock(threading.RLock(), "A", tr)
    lb = TrackedLock(threading.RLock(), "B", tr)
    for _ in range(3):
        with la:
            with lb:
                pass
    assert tr.edges() == {"A": {"B"}}


def test_debug_store_wraps_locks_and_runs_clean():
    """The debug_sync store wraps both of its locks in TrackedLock, and the
    ingest -> fence -> fetch path runs without a violation (the store never
    nests _lock inside _futs_lock or vice versa — the invariant locklint
    checks statically)."""
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec="int4",
                        debug_sync=True)
    try:
        assert isinstance(st_._lock, TrackedLock)
        assert st_._lock.name == "TieredKVStore._lock"
        assert isinstance(st_._futs_lock, TrackedLock)
        rng = np.random.RandomState(0)
        k = rng.randn(32, 2, 8).astype(np.float16)
        v = rng.randn(32, 2, 8).astype(np.float16)
        st_.ingest(0, k, v, seq=0)
        st_.ingest_fence(0)
        kf, _ = st_.fetch_chunks(0, [0, 1])
        np.testing.assert_allclose(
            kf.reshape(32, 2, 8).astype(np.float32),
            k.astype(np.float32), atol=0.25)
        edges = sanitizer.lock_order_edges()
        assert not any("TieredKVStore._futs_lock" in e
                       for e in edges.get("TieredKVStore._lock", ()))
    finally:
        st_.close()


# ----------------------------------------------------------------------
# schedule-fuzz stress test: full engine under debug_sync
# ----------------------------------------------------------------------
_SETUP = {}


def _setup():
    if not _SETUP:
        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.RandomState(7)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, n)
                             for n in (48, 57, 64)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _drive(order, *, debug_sync, jitter_rng=None, max_new=3):
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
    from repro.serving.scheduler import ContinuousBatcher, Request, \
        SchedulerCfg
    cfg, params, prompts = _setup()
    eng = BatchedLeoAMEngine(
        cfg, params,
        EngineCfg(max_len=128, selection="tree", overlap_ingest=True,
                  disk_sidecar=True, debug_sync=debug_sync),
        max_seqs=2)
    b = ContinuousBatcher(
        cfg=SchedulerCfg(max_active=2, chunk=16, overlap_admission=True),
        engine=eng)
    for i in order:
        b.submit(Request(i, prompts[i], max_new=max_new))
        if jitter_rng is not None:
            # perturb the worker/decode interleaving between submissions
            time.sleep(float(jitter_rng.rand()) * 2e-3)
    out = {r.rid: r.out for r in b.run()}
    eng.store.close()
    return out


_REF = {}


@pytest.mark.stress
@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=9))
def test_schedule_fuzz_debug_sync_token_identical(seed):
    """Randomized admission order + worker-timing jitter under the live
    sanitizer: no SyncViolation fires and the token streams match the
    non-debug engine exactly — the instrumentation observes, never
    perturbs."""
    rng = np.random.RandomState(seed)
    order = list(rng.permutation(3))
    key = tuple(order)
    if key not in _REF:
        _REF[key] = _drive(order, debug_sync=False)
    was_active = sanitizer.active()
    got = _drive(order, debug_sync=True, jitter_rng=rng)
    assert sanitizer.active() == was_active   # close() released the refcount
    ref = _REF[key]
    assert set(got) == set(ref)
    for rid in ref:
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
