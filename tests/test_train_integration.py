"""End-to-end training: loss decreases on the synthetic corpus; checkpoint
restart resumes bit-exact; fault-tolerant driver survives injected failures."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.synthetic import DataCfg, ShardedLoader, pack_documents, SyntheticCorpus
from repro.launch import steps as stp
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerMonitor, run_with_restarts


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    tcfg = stp.TrainCfg(lr=3e-3, warmup_steps=5, total_steps=200,
                        schedule="warmup_cosine")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params,
             "opt": adamw.init_opt_state(params, tcfg.adam)}
    step = jax.jit(stp.make_train_step(cfg, tcfg))
    dcfg = DataCfg(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    loader = ShardedLoader(dcfg)
    return cfg, tcfg, state, step, loader


def test_loss_decreases(tiny_setup):
    cfg, tcfg, state, step, loader = tiny_setup
    losses = []
    for i, batch in zip(range(30), loader):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in batch.items()})
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_checkpoint_resume_exact(tmp_path, tiny_setup):
    cfg, tcfg, state, step, loader = tiny_setup
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    batches = [next(loader) for _ in range(6)]
    s = jax.tree.map(jnp.copy, state)
    for b in batches[:3]:
        s, _ = step(s, {k: jnp.asarray(v) for k, v in b.items()})
    ck.save(3, s, block=True)
    sA = s
    for b in batches[3:]:
        sA, mA = step(sA, {k: jnp.asarray(v) for k, v in b.items()})
    restored, at = ck.restore(jax.tree.map(np.asarray, s))
    assert at == 3
    sB = jax.tree.map(jnp.asarray, restored)
    for b in batches[3:]:
        sB, mB = step(sB, {k: jnp.asarray(v) for k, v in b.items()})
    for a, b2 in zip(jax.tree.leaves(sA["params"]), jax.tree.leaves(sB["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b2))


def test_fault_tolerant_driver(tmp_path, tiny_setup):
    cfg, tcfg, state, step, loader = tiny_setup
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    batches = [next(loader) for _ in range(12)]
    fail_at = {5: True, 8: True}

    def step_fn(i, s):
        if fail_at.pop(i, False):
            raise RuntimeError("injected worker failure")
        s, _ = step(s, {k: jnp.asarray(v) for k, v in batches[i].items()})
        return s

    def restore_fn(s):
        tpl = jax.tree.map(np.asarray, s)
        restored, at = ck.restore(tpl)
        return jax.tree.map(jnp.asarray, restored), at

    ck.save(0, state, block=True)
    mon = StragglerMonitor()
    final, stats = run_with_restarts(
        step_fn, state, n_steps=12, checkpointer=ck, save_every=2,
        restore_fn=restore_fn, max_restarts=5, monitor=mon)
    assert stats.restarts == 2
    assert int(np.asarray(final["opt"]["step"])) == 12


def test_checkpoint_gc_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"w": np.arange(10, dtype=np.float32)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, block=True)
    assert ck.all_steps() == [3, 4]
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_packing_determinism_and_shard_disjointness():
    dcfg = DataCfg(vocab_size=512, seq_len=32, global_batch=4)
    c = SyntheticCorpus(dcfg)
    a1, _ = pack_documents(c, 32, 0, 4)
    a2, _ = pack_documents(c, 32, 0, 4)
    np.testing.assert_array_equal(a1, a2)
    l0 = ShardedLoader(dcfg, host_id=0, n_hosts=2)
    l1 = ShardedLoader(dcfg, host_id=1, n_hosts=2)
    b0, b1 = next(l0), next(l1)
    assert b0["tokens"].shape == (2, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    l0.close(); l1.close()
