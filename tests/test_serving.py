"""Serving: tier store accounting (LKA ratio), engine end-to-end generation,
simulator reproduction bands (paper Figs. 15-17)."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.tiers import lka_transfer_ratio
from repro.models import lm
from repro.serving.engine import EngineCfg, LeoAMEngine
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.simulator import (HWCfg, POLICIES, ServeCfg,
                                     compare_policies, simulate_decode)


# ---------------------------------------------------------------------------
# Tier store
# ---------------------------------------------------------------------------


def test_store_abstract_vs_full_traffic(rng):
    st = TieredKVStore(n_layers=1, n_chunks=8, chunk=16, kv_heads=2,
                       head_dim=8, transit_codec=None)
    k = rng.randn(128, 2, 8).astype(np.float16)
    v = rng.randn(128, 2, 8).astype(np.float16)
    st.ingest(0, k, v, {c: DISK for c in range(8)})
    st.read_abstracts(0, list(range(8)))
    ab = st.log.total(src=DISK, kind="abstract")
    assert ab == 8 * st.abstract_bytes
    st.fetch_chunks(0, [0, 3])
    moved = st.log.total(src=DISK, kind="kv")
    assert moved == 2 * st.chunk_bytes
    # LKA ratio: abstracts + selected vs full
    r = (ab + moved) / (8 * st.chunk_bytes)
    expect = lka_transfer_ratio(alpha=2 / 8, chunk=16) / 2 + 2 / 8
    # abstracts are keys only (half of K+V), formula's 2/n' counts keys;
    # just assert the saving is large:
    assert r < 0.45
    st.close()


def test_store_disk_replica_free_demotion(rng):
    st = TieredKVStore(1, 4, 8, 2, 8, transit_codec=None)
    k = rng.randn(32, 2, 8).astype(np.float16)
    st.ingest(0, k, k, {c: HOST for c in range(4)})
    before = st.log.total(kind="kv")
    st.demote(0, [1, 2], to=DISK)
    assert st.log.total(kind="kv") == before       # no write I/O
    kf, vf = st.fetch_chunks(0, [1])
    np.testing.assert_allclose(kf[0], k[8:16], atol=1e-3)
    st.close()


def test_store_append_updates_abstract(rng):
    st = TieredKVStore(1, 4, 8, 2, 4, transit_codec=None)
    k = rng.randn(16, 2, 4).astype(np.float16)
    st.ingest(0, k, k, {c: HOST for c in range(4)})
    newk = np.full((2, 4), 9.0, np.float16)
    st.append_token(0, 16, newk, newk)
    km, kn = st.read_abstracts(0, [2])
    assert np.all(km[0] >= 9.0 - 1e-3)
    st.close()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.4, early_rate=0.6,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def test_engine_generates_and_audits(engine_setup, rng):
    cfg, params = engine_setup
    eng = LeoAMEngine(cfg, params, EngineCfg(max_len=256, selection="tree"))
    prompt = rng.randint(2, cfg.vocab_size, 128)
    toks = eng.generate(prompt, 8)
    assert len(toks) == 8
    assert all(0 <= t < cfg.vocab_size for t in toks)
    # traffic audit: abstracts moved from disk, full KV only for selections
    total_kv = eng.store.log.total(kind="kv")
    assert total_kv > 0
    assert eng.store.log.total(kind="abstract") > 0
    # evaluations were adaptive (fewer than token-level = length per layer)
    st = eng.stats[-1]
    n_attn = len(eng.attn_layers)
    assert st.evaluations < eng.length * n_attn
    eng.store.close()


def test_engine_matches_untieried_decode_at_full_budget(engine_setup, rng):
    """With budget ~= all chunks + flat selection, the engine's token stream
    equals the plain lm.decode_step stream (numerical tiering fidelity)."""
    cfg, params = engine_setup
    cfg_full = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, importance_rate=1.0,
                                       early_rate=1.0))
    eng = LeoAMEngine(cfg_full, params,
                      EngineCfg(max_len=128, selection="flat",
                                transit_codec=None))
    prompt = rng.randint(2, cfg.vocab_size, 64)
    got = eng.generate(prompt, 6)
    # reference: plain decode
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, cache = lm.prefill(params, cfg_full, batch, max_len=128)
    tok = int(jnp.argmax(logits[0]))
    ref = [tok]
    length = len(prompt)
    for _ in range(5):
        logits, cache = lm.decode_step(params, cfg_full, cache,
                                       {"token": jnp.asarray([tok], jnp.int32)},
                                       jnp.int32(length))
        tok = int(jnp.argmax(logits[0]))
        ref.append(tok)
        length += 1
    assert got == ref, (got, ref)
    eng.store.close()


# ---------------------------------------------------------------------------
# Simulator (paper bands)
# ---------------------------------------------------------------------------


def test_policy_ordering():
    cfg = get_config("longchat-7b-32k")
    res = compare_policies(cfg, ServeCfg(batch=4, prompt=8192, output=64))
    assert res["leoam_all"]["total_s"] < res["leoam_iakm"]["total_s"]
    assert res["leoam_iakm"]["total_s"] < res["leoam_lka"]["total_s"]
    assert res["leoam_lka"]["total_s"] < res["h2o"]["total_s"]
    assert res["h2o"]["total_s"] < res["full"]["total_s"]


def test_paper_speedup_bands():
    """Avg speedup vs best baseline ~3.46x (paper), max ~5.47x at batch 8."""
    cfg = get_config("longchat-7b-32k")
    sps = []
    for b in (1, 4, 8):
        res = compare_policies(cfg, ServeCfg(batch=b, prompt=8192, output=128))
        base = min(res[p]["total_s"] for p in ("h2o", "h2o_chunked", "prefetch"))
        sps.append(base / res["leoam_all"]["total_s"])
    avg, mx = float(np.mean(sps)), float(np.max(sps))
    assert 2.8 <= avg <= 4.2, sps
    assert 4.6 <= mx <= 6.5, sps


def test_decode_step_transfer_dominates_baseline():
    """Paper Fig. 6: transmission (eval transit + KV movement) dominates
    compute for naive offloading (their 2K/b4 measurement: 290 vs 100 ms)."""
    cfg = get_config("longchat-7b-32k")
    step = simulate_decode(cfg, ServeCfg(batch=4, prompt=2048, gpu_frac=0.1,
                                         cpu_frac=0.5), HWCfg(), "h2o")
    transmission = step.transfer_s + step.eval_s
    assert transmission > 1.2 * step.compute_s
