"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (the deliverable-(c) kernel contract)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_bounds.ops import chunk_bounds
from repro.kernels.kv_quant.ops import kv_dequant
from repro.kernels.pq.ops import pq_assign, pq_train, pq_update
from repro.kernels.sparse_decode.ops import sparse_decode


@pytest.mark.parametrize("B,Hkv,G,hd,nc", [
    (1, 1, 1, 8, 4), (2, 4, 2, 32, 16), (1, 2, 3, 128, 7),
    (2, 8, 1, 64, 130), (1, 16, 6, 192, 33),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_chunk_bounds_kernel(rng, B, Hkv, G, hd, nc, dtype):
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32)).astype(dtype)
    km = jnp.asarray(rng.randn(B, Hkv, nc, hd).astype(np.float32))
    kn = km - jnp.asarray(np.abs(rng.randn(B, Hkv, nc, hd)).astype(np.float32))
    ub_r, lb_r = chunk_bounds(q, km, kn, impl="ref")
    ub_k, lb_k = chunk_bounds(q, km, kn, impl="interpret")
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(ub_r, ub_k, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(lb_r, lb_k, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("B,Hkv,G,hd,S,chunk,nsel", [
    (1, 1, 1, 8, 64, 8, 3), (2, 2, 2, 32, 128, 16, 4),
    (1, 4, 1, 128, 256, 64, 3), (2, 1, 3, 64, 512, 32, 8),
    (1, 2, 4, 192, 256, 128, 2),
])
@pytest.mark.parametrize("kv_dtype", [np.float32, jnp.bfloat16])
def test_sparse_decode_kernel(rng, B, Hkv, G, hd, S, chunk, nsel, kv_dtype):
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32) / np.sqrt(hd))
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32)).astype(kv_dtype)
    v = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32)).astype(kv_dtype)
    nc = S // chunk
    ids = jnp.asarray(np.stack([
        np.stack([rng.choice(nc, nsel, replace=False) for _ in range(Hkv)])
        for _ in range(B)]).astype(np.int32))
    length = jnp.int32(S - chunk // 2)
    outs_r = sparse_decode(q, k, v, ids, length, chunk=chunk, impl="ref")
    outs_k = sparse_decode(q, k, v, ids, length, chunk=chunk, impl="interpret")
    tol = 1e-5 if kv_dtype == np.float32 else 2e-2
    for r, kk in zip(outs_r, outs_k):
        np.testing.assert_allclose(r, kk, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("codec", ["int8", "int4"])
@pytest.mark.parametrize("N,c,d", [(1, 8, 16), (4, 16, 64), (2, 64, 128),
                                   (3, 32, 256)])
def test_kv_dequant_kernel(rng, codec, N, c, d):
    dp = d if codec == "int8" else d // 2
    data = jnp.asarray(rng.randint(-128, 128, (N, c, dp)).astype(np.int8))
    scale = jnp.asarray(np.abs(rng.randn(N, d)).astype(np.float32) + 0.01)
    o_r = kv_dequant(data, scale, codec=codec, impl="ref")
    o_k = kv_dequant(data, scale, codec=codec, impl="interpret")
    np.testing.assert_allclose(np.asarray(o_r, np.float32),
                               np.asarray(o_k, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("m,N,dsub,K", [
    (1, 8, 8, 4), (2, 100, 8, 16), (4, 257, 16, 32), (3, 512, 4, 256),
])
def test_pq_assign_kernel(rng, m, N, dsub, K):
    """Nearest-centroid assignment: interpret-mode kernel vs jnp oracle.
    Codes compare EXACTLY — both use the same distance expression, so
    argmin tie-breaking matches."""
    x = jnp.asarray(rng.randn(m, N, dsub).astype(np.float32))
    cb = jnp.asarray(rng.randn(m, K, dsub).astype(np.float32))
    c_r = pq_assign(x, cb, impl="ref")
    c_k = pq_assign(x, cb, impl="interpret")
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_k))
    # optimality: the chosen centroid is a true argmin of the l2 distance
    xs, cbs = np.asarray(x), np.asarray(cb)
    d = ((xs[:, :, None, :] - cbs[:, None, :, :]) ** 2).sum(-1)  # (m,N,K)
    chosen = np.take_along_axis(d, np.asarray(c_r)[..., None], 2)[..., 0]
    np.testing.assert_allclose(chosen, d.min(-1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,N,dsub,K", [
    (1, 8, 8, 4), (2, 100, 8, 16), (4, 257, 16, 32),
])
def test_pq_update_kernel(rng, m, N, dsub, K):
    """Lloyd accumulation (one-hot matmul sums + counts): interpret vs
    oracle, and counts conserve the row total."""
    x = jnp.asarray(rng.randn(m, N, dsub).astype(np.float32))
    codes = jnp.asarray(rng.randint(0, K, (m, N)).astype(np.int32))
    s_r, n_r = pq_update(x, codes, K, impl="ref")
    s_k, n_k = pq_update(x, codes, K, impl="interpret")
    np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_k),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n_r), np.asarray(n_k))
    np.testing.assert_allclose(np.asarray(n_k).sum(-1), N)


def test_pq_kernel_degenerate_inputs(rng):
    """Constant keys collapse every code to one centroid without NaNs,
    and a batch smaller than the codebook (n < K) still trains."""
    m, dsub, K = 2, 8, 16
    const = np.ones((m, 40, dsub), np.float32) * 3.0
    cb0 = np.zeros((m, K, dsub), np.float32)
    cnt0 = np.zeros((m, K), np.float64)
    cb, cnt = pq_train(const.transpose(1, 0, 2).reshape(40, m * dsub),
                       cb0, cnt0, iters=3, impl="interpret")
    assert np.isfinite(cb).all()
    codes = pq_assign(jnp.asarray(const), jnp.asarray(cb),
                      impl="interpret")
    # all rows identical -> one centroid wins everywhere (per subspace)
    assert all(len(np.unique(np.asarray(codes)[i])) == 1 for i in range(m))
    # n < n_centroids: strided init duplicates rows; still finite, and
    # every vector maps to a centroid equal to itself (exact round-trip)
    few = rng.randn(5, m * dsub).astype(np.float32)
    cb2, _ = pq_train(few, cb0, cnt0, iters=4, impl="interpret")
    assert np.isfinite(cb2).all()
    from repro.kernels.pq.ops import pq_decode, pq_encode
    dec = pq_decode(pq_encode(few, cb2, impl="interpret"), cb2)
    np.testing.assert_allclose(dec, few, rtol=1e-4, atol=1e-4)


def test_sparse_decode_kernel_vs_dense_full_budget(rng):
    """Kernel with all chunks selected reproduces dense attention."""
    B, Hkv, G, hd, S, chunk = 1, 2, 2, 32, 128, 16
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32) / np.sqrt(hd))
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    nc = S // chunk
    ids = jnp.broadcast_to(jnp.arange(nc, dtype=jnp.int32), (B, Hkv, nc))
    num, den, m = sparse_decode(q, k, v, ids, jnp.int32(S), chunk=chunk,
                                impl="interpret")
    out = np.asarray(num / den[..., None])
    s = np.einsum("bkgd,bskd->bkgs", np.asarray(q), np.asarray(k))
    e = np.exp(s - s.max(-1, keepdims=True))
    ref = np.einsum("bkgs,bskd->bkgd", e / e.sum(-1, keepdims=True),
                    np.asarray(v))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
