"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (the deliverable-(c) kernel contract)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_bounds.ops import chunk_bounds
from repro.kernels.kv_quant.ops import kv_dequant
from repro.kernels.sparse_decode.ops import sparse_decode


@pytest.mark.parametrize("B,Hkv,G,hd,nc", [
    (1, 1, 1, 8, 4), (2, 4, 2, 32, 16), (1, 2, 3, 128, 7),
    (2, 8, 1, 64, 130), (1, 16, 6, 192, 33),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_chunk_bounds_kernel(rng, B, Hkv, G, hd, nc, dtype):
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32)).astype(dtype)
    km = jnp.asarray(rng.randn(B, Hkv, nc, hd).astype(np.float32))
    kn = km - jnp.asarray(np.abs(rng.randn(B, Hkv, nc, hd)).astype(np.float32))
    ub_r, lb_r = chunk_bounds(q, km, kn, impl="ref")
    ub_k, lb_k = chunk_bounds(q, km, kn, impl="interpret")
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(ub_r, ub_k, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(lb_r, lb_k, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("B,Hkv,G,hd,S,chunk,nsel", [
    (1, 1, 1, 8, 64, 8, 3), (2, 2, 2, 32, 128, 16, 4),
    (1, 4, 1, 128, 256, 64, 3), (2, 1, 3, 64, 512, 32, 8),
    (1, 2, 4, 192, 256, 128, 2),
])
@pytest.mark.parametrize("kv_dtype", [np.float32, jnp.bfloat16])
def test_sparse_decode_kernel(rng, B, Hkv, G, hd, S, chunk, nsel, kv_dtype):
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32) / np.sqrt(hd))
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32)).astype(kv_dtype)
    v = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32)).astype(kv_dtype)
    nc = S // chunk
    ids = jnp.asarray(np.stack([
        np.stack([rng.choice(nc, nsel, replace=False) for _ in range(Hkv)])
        for _ in range(B)]).astype(np.int32))
    length = jnp.int32(S - chunk // 2)
    outs_r = sparse_decode(q, k, v, ids, length, chunk=chunk, impl="ref")
    outs_k = sparse_decode(q, k, v, ids, length, chunk=chunk, impl="interpret")
    tol = 1e-5 if kv_dtype == np.float32 else 2e-2
    for r, kk in zip(outs_r, outs_k):
        np.testing.assert_allclose(r, kk, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("codec", ["int8", "int4"])
@pytest.mark.parametrize("N,c,d", [(1, 8, 16), (4, 16, 64), (2, 64, 128),
                                   (3, 32, 256)])
def test_kv_dequant_kernel(rng, codec, N, c, d):
    dp = d if codec == "int8" else d // 2
    data = jnp.asarray(rng.randint(-128, 128, (N, c, dp)).astype(np.int8))
    scale = jnp.asarray(np.abs(rng.randn(N, d)).astype(np.float32) + 0.01)
    o_r = kv_dequant(data, scale, codec=codec, impl="ref")
    o_k = kv_dequant(data, scale, codec=codec, impl="interpret")
    np.testing.assert_allclose(np.asarray(o_r, np.float32),
                               np.asarray(o_k, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_sparse_decode_kernel_vs_dense_full_budget(rng):
    """Kernel with all chunks selected reproduces dense attention."""
    B, Hkv, G, hd, S, chunk = 1, 2, 2, 32, 128, 16
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32) / np.sqrt(hd))
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    nc = S // chunk
    ids = jnp.broadcast_to(jnp.arange(nc, dtype=jnp.int32), (B, Hkv, nc))
    num, den, m = sparse_decode(q, k, v, ids, jnp.int32(S), chunk=chunk,
                                impl="interpret")
    out = np.asarray(num / den[..., None])
    s = np.einsum("bkgd,bskd->bkgs", np.asarray(q), np.asarray(k))
    e = np.exp(s - s.max(-1, keepdims=True))
    ref = np.einsum("bkgs,bskd->bkgd", e / e.sum(-1, keepdims=True),
                    np.asarray(v))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
