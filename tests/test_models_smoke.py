"""Per-arch smoke tests (deliverable f): every assigned architecture's
reduced config runs one forward/train step and one prefill+decode step on
CPU with finite outputs and correct shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import lm

ARCHS = list_configs()


def make_batch(cfg, rng, B=2, S=32):
    batch = {}
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.embed_inputs and not cfg.is_encdec:
        batch["embeds"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None],
                                   (3, B, S))
            batch["positions"] = pos
    else:
        batch["tokens"] = toks
    if cfg.is_encdec:
        batch["embeds"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)
        batch["tokens"] = toks
    batch["targets"] = toks
    return batch, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    batch, _ = make_batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: lm.forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode logits equal full-forward logits (dense mode)."""
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, min_seq_for_sparse=10**9))
    if cfg.moe is not None:                  # avoid capacity-drop mismatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch, toks = make_batch(cfg, rng, B, S)
    if cfg.embed_inputs and not cfg.is_encdec:
        # decode embeds generated tokens via the table; feed the same
        # embeddings at prefill so the streams are comparable
        batch["embeds"] = jnp.take(params["embed"], toks, axis=0
                                   ).astype(jnp.float32)
    pre = dict(batch)
    pre.pop("targets")
    if "tokens" in pre and not cfg.is_encdec:
        pre["tokens"] = toks[:, : S - 1]
    elif cfg.is_encdec:
        pre["tokens"] = toks[:, : S - 1]
    elif "embeds" in pre:
        pre["embeds"] = pre["embeds"][:, : S - 1]
        if "positions" in pre:
            pre["positions"] = pre["positions"][:, :, : S - 1]
    _, cache = lm.prefill(params, cfg, pre, max_len=S)
    logits_dec, cache2 = lm.decode_step(params, cfg, cache,
                                        {"token": toks[:, S - 1]},
                                        jnp.int32(S - 1))
    full = dict(batch)
    full.pop("targets")
    logits_full, _ = lm.prefill(params, cfg, full, max_len=S)
    assert logits_dec.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_dec)))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "qwen3-1.7b",
                                  "deepseek-v2-lite-16b", "gemma2-2b"])
def test_sparse_decode_path_runs(arch, rng):
    """LeoAM sparse selection active in decode (budgeted attention)."""
    cfg = get_config(arch, smoke=True)  # min_seq_for_sparse=32 in smoke
    params = lm.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    batch, toks = make_batch(cfg, rng, B, S)
    pre = {"tokens": toks[:, : S - 1]}
    _, cache = lm.prefill(params, cfg, pre, max_len=S)
    logits, cache2 = lm.decode_step(params, cfg, cache,
                                    {"token": toks[:, S - 1]},
                                    jnp.int32(S - 1))
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache was updated in place at position S-1
    lk = cache2["prologue"][0].get("k")
    if lk is None:
        lk = cache2["prologue"][0].get("ckv")
    assert bool(jnp.any(jnp.abs(np.asarray(lk)[:, S - 1]) > 0))


def test_param_counts_match_analytic():
    """init() materializes ~ the analytic n_params for a dense arch."""
    cfg = get_config("qwen3-1.7b", smoke=True)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    expect = cfg.n_params()
    assert abs(n - expect) / expect < 0.05, (n, expect)
