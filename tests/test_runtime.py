"""Elastic re-meshing + HLO cost parser unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_costing import HloCost, analyze
from repro.runtime.elastic import (choose_grid, make_mesh_from_devices,
                                   reshard_tree, shrink_batch_for)


def test_choose_grid():
    assert choose_grid(512, prefer_model=16) == (32, 16)
    assert choose_grid(256, prefer_model=16) == (16, 16)
    assert choose_grid(24, prefer_model=16) == (3, 8)
    assert choose_grid(7, prefer_model=16) == (7, 1)


def test_shrink_batch():
    mesh = make_mesh_from_devices(jax.devices())   # 1 device
    assert shrink_batch_for(256, mesh) == 256


def test_reshard_tree_roundtrip():
    mesh = make_mesh_from_devices(jax.devices())
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    axes = {"w": ("embed", "ffn")}
    out = reshard_tree(tree, axes, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_hlo_parser_scales_scan_by_trip_count():
    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    res = analyze(c.as_text(), 1)
    expect = 8 * 2 * 128 * 256 * 256
    assert abs(res["flops"] - expect) / expect < 0.05, res["flops"]


def test_hlo_parser_counts_collectives():
    import os
    # single-device: no collectives
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    res = analyze(c.as_text(), 1)
    assert res["total_collective_bytes"] == 0.0
    assert res["flops"] == pytest.approx(2 * 64**3, rel=0.05)
