"""Bucketed + chunked prefill (PR 4): O(log L) compiled prefill programs,
resumable chunked admission interleaved with decode, partial-sequence
ingest, contention-aware admission pacing, and the sidecar requantization
sweep — token parity, program-count, billing and gate-state guarantees."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compression
from repro.core.pipeline import chunked_admission_model
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg

_SETUP = {}


def _setup():
    """Module-lazy smoke model (the hypothesis shim can't take fixtures)."""
    if not _SETUP:
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        _SETUP["rng"] = np.random.RandomState(7)
    return _SETUP["cfg"], _SETUP["params"]


def _ecfg(**kw):
    from repro.serving.engine import EngineCfg
    return EngineCfg(max_len=128, selection="tree", **kw)


def _engine(max_seqs=1, **kw):
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params = _setup()
    return BatchedLeoAMEngine(cfg, params, _ecfg(**kw), max_seqs=max_seqs)


def _gen(eng, prompt, n_new=3):
    sid, tok = eng.add_sequence(prompt)
    out = [tok]
    toks = {sid: tok}
    for _ in range(n_new):
        toks = eng.decode_round(toks)
        out.append(toks[sid])
    eng.release(sid)
    return out


# ---------------------------------------------------------------------------
# Bucketed prefill
# ---------------------------------------------------------------------------


_ENGINES = {}


def _bucket_pair():
    """Persistent (exact, bucketed) engine pair — jit caches amortize
    across the parametrized lengths."""
    if not _ENGINES:
        _ENGINES["exact"] = _engine(bucket_prefill=False)
        _ENGINES["bucket"] = _engine(bucket_prefill=True)
    return _ENGINES["exact"], _ENGINES["bucket"]


@pytest.mark.parametrize("L", [31, 32, 33, 63, 64, 65])
def test_bucketed_prefill_token_identical_at_bucket_edges(L):
    """Property (bucket edges L, L±1): padding the prompt to its length
    bucket with the validity mask threaded through prefill decodes the
    EXACT token stream of exact-length prefill — padded keys are causally
    invisible and bucket-padding cache rows ingest as zeros, exactly like
    the exact path's pad rows."""
    cfg, _ = _setup()
    prompt = np.random.RandomState(100 + L).randint(2, cfg.vocab_size, L)
    exact, bucket = _bucket_pair()
    assert _gen(bucket, prompt) == _gen(exact, prompt)


def test_mixed_lengths_compile_log_programs():
    """Acceptance: >= 16 distinct prompt lengths compile at most
    ceil(log2(max_len)) + 2 prefill programs (one per LENGTH today would be
    16+), with first tokens matching the exact-length path."""
    cfg, _ = _setup()
    exact, bucket = _bucket_pair()
    rng = np.random.RandomState(11)
    lengths = list(range(17, 113, 6))          # 16 distinct lengths
    assert len(set(lengths)) >= 16
    for L in lengths:
        p = rng.randint(2, cfg.vocab_size, L)
        sid_b, tok_b = bucket.add_sequence(p)
        bucket.release(sid_b)
        sid_e, tok_e = exact.add_sequence(p)
        exact.release(sid_e)
        assert tok_b == tok_e, L
    limit = math.ceil(math.log2(bucket.ecfg.max_len)) + 2
    assert bucket.prefill_programs <= limit, (bucket.prefill_programs, limit)
    # the exact engine really does compile per length (the regression the
    # bucket schedule kills)
    assert exact.prefill_programs >= len(lengths)


def test_masked_state_scan_ignores_padding():
    """The recurrent-layer prefill helper: bucket-padding rows are identity
    for the carried state (mamba/xlstm states stop at ``length``)."""
    from repro.models.lm import _masked_state_scan
    x = jnp.asarray(np.random.RandomState(0).randn(2, 8, 4))
    step = lambda c, xt: c * 0.5 + xt
    exact = _masked_state_scan(step, jnp.zeros((2, 4)), x[:, :5], None)
    padded = _masked_state_scan(step, jnp.zeros((2, 4)), x, jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(padded))


# ---------------------------------------------------------------------------
# Chunked admission
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_chunked_admission_interleaved_matches_serial(seed):
    """Property: chunked admission stepped at RANDOM interleavings with a
    running sequence's decode rounds produces token streams identical to
    whole-prompt admission at the same round schedule — chunk boundaries
    move residency and latency, never values."""
    cfg, _ = _setup()
    rng = np.random.RandomState(seed)
    pa = rng.randint(2, cfg.vocab_size, 41)
    pb = rng.randint(2, cfg.vocab_size, 57)
    pre_rounds = int(rng.randint(0, 3))        # rounds of A before B starts
    interleave = [bool(b) for b in rng.randint(2, size=8)]  # round after
                                               # chunk i of B's admission?

    def run(chunked: bool):
        eng = _engine(max_seqs=2, prefill_chunk_tokens=32)
        sa_, ta = eng.add_sequence(pa)
        outs = {sa_: [ta]}
        toks = {sa_: ta}
        for _ in range(pre_rounds):
            toks = eng.decode_round(toks)
            outs[sa_].append(toks[sa_])
        if chunked:
            adm = eng.begin_admission(pb)
            for do_round in interleave:
                adm.step()                     # one chunk ...
                if adm.done:
                    break
                if do_round:
                    toks = eng.decode_round(toks)   # ... then maybe a round
                    outs[sa_].append(toks[sa_])
            sb, tb = adm.drain()
        else:
            sb, tb = eng.add_sequence(pb)
        outs[sb] = [tb]
        toks[sb] = tb
        for _ in range(3):
            toks = eng.decode_round(toks)
            for s, t in toks.items():
                outs[s].append(t)
        eng.store.close()
        # A's stream length differs by the interleaving; compare the
        # common prefix of A and all of B
        return outs[sa_], outs[sb]

    a_chunk, b_chunk = run(True)
    a_ser, b_ser = run(False)
    n = min(len(a_chunk), len(a_ser))
    assert a_chunk[:n] == a_ser[:n]
    assert b_chunk == b_ser


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_scheduler_chunked_admission_arrival_order_parity(seed):
    """Property: the batcher's chunked-admission mode (budgeted chunk steps
    between rounds) matches serial admission token-for-token for every
    arrival order and budget."""
    cfg, params = _setup()
    from repro.serving.engine import BatchedLeoAMEngine
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 57, 64, 50)]
    order = list(rng.permutation(4))
    budget = int(rng.choice([16, 32, 64]))

    def drive(chunked: bool):
        eng = BatchedLeoAMEngine(cfg, params,
                                 _ecfg(prefill_chunk_tokens=16),
                                 max_seqs=3)
        b = ContinuousBatcher(
            cfg=SchedulerCfg(max_active=2, chunk=16,
                             chunked_admission=chunked,
                             prefill_round_tokens=budget),
            engine=eng)
        for i in order:
            b.submit(Request(i, prompts[i], max_new=4))
        out = {r.rid: r.out for r in b.run()}
        eng.store.close()
        return out

    assert drive(True) == drive(False), (order, budget)


def test_partial_ingest_matches_whole(rng):
    """Chunk-aligned partial ingest (start=...) lands the same replicas,
    abstracts, tiers and billed bytes as one whole-sequence ingest."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    v = rng.randn(64, 2, 8).astype(np.float16)
    place = {0: DEVICE, 1: HOST, 2: DISK, 3: DISK}
    whole = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec="int4")
    whole.ingest(0, k, v, place)
    part = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec="int4")
    for start in (0, 32):
        part.ingest(0, k[start:start + 32], v[start:start + 32], place,
                    start=start)
    np.testing.assert_array_equal(np.asarray(whole._disk),
                                  np.asarray(part._disk))
    np.testing.assert_array_equal(whole._abs_km, part._abs_km)
    np.testing.assert_array_equal(whole._abs_kn, part._abs_kn)
    assert list(whole.tier[0, 0]) == list(part.tier[0, 0])
    assert whole.log.total() == part.log.total()
    kw, _ = whole.fetch_chunks(0, [0, 1, 2, 3])
    kp, _ = part.fetch_chunks(0, [0, 1, 2, 3])
    np.testing.assert_array_equal(kw, kp)
    whole.close()
    part.close()


def test_unaligned_partial_ingest_rejected(rng):
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None)
    k = rng.randn(16, 2, 8).astype(np.float16)
    with pytest.raises(AssertionError):
        st_.ingest(0, k, k, {}, start=8)
    st_.close()


# ---------------------------------------------------------------------------
# Contention-aware admission pacing
# ---------------------------------------------------------------------------


def test_admission_pacing_gate_closes_and_reopens():
    """The pacing gate: inflated rounds (vs the idle baseline) close it,
    cool rounds reopen it, and a closed gate blocks chunk advancement
    while decode is active (counted in gated_rounds / stats)."""
    b = ContinuousBatcher(make_engine=lambda: None,
                          cfg=SchedulerCfg(pace_admission=True,
                                           max_round_inflation=0.3,
                                           ewma_alpha=0.5))
    for _ in range(4):                       # idle baseline ~0.1
        b._note_round(0.1, admission_active=False)
    assert b._gate_open
    for _ in range(4):                       # admission inflates rounds 3x
        b._note_round(0.3, admission_active=True)
    assert not b._gate_open

    class _Adm:
        done = False
        def step(self):
            raise AssertionError("gated admission must not advance")
    b._chunked = [(Request(0, np.arange(4), max_new=1), _Adm())]
    b.active[9] = (Request(9, np.arange(4), max_new=8), 0, 1)
    b._advance_chunked()                     # gate closed: no step()
    assert b._gated_rounds == 1
    stt = b.stats()
    assert stt["admission_gate_open"] == 0.0
    assert stt["gated_rounds"] == 1.0
    assert stt["round_ewma_s"] > stt["idle_round_ewma_s"]
    for _ in range(8):                       # admission paused: rounds cool
        b._note_round(0.1, admission_active=False)
    assert b._gate_open


def test_pacing_gate_open_allows_chunked_progress():
    """With ample inflation headroom the gate stays open end to end and
    chunked admission completes normally (plumbed through run())."""
    cfg, params = _setup()
    from repro.serving.engine import BatchedLeoAMEngine
    eng = BatchedLeoAMEngine(cfg, params, _ecfg(prefill_chunk_tokens=32),
                             max_seqs=3)
    b = ContinuousBatcher(
        cfg=SchedulerCfg(max_active=2, chunk=16, chunked_admission=True,
                         prefill_round_tokens=32, pace_admission=True,
                         max_round_inflation=1e6),
        engine=eng)
    rng = np.random.RandomState(0)
    for i in range(3):
        b.submit(Request(i, rng.randint(2, cfg.vocab_size, 48), max_new=3))
    done = b.run()
    assert len(done) == 3
    assert b.stats()["admission_gate_open"] == 1.0
    eng.store.close()


# ---------------------------------------------------------------------------
# Sidecar requantization sweep
# ---------------------------------------------------------------------------


def test_requant_sweep_repacks_quiet_chunks(rng):
    """An append-dirtied chunk is re-packed after one FULL quiet round:
    reads bill packed bytes again, values (incl. the appended row) sit
    within the quantization bound, and repacks are counted in the traffic
    log.  The live tail chunk (appended every round) is never repacked."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st_ = TieredKVStore(1, 8, 16, 2, 8, n_seqs=1, transit_codec="int4",
                        use_pool=True, disk_sidecar=True)
    st_.ingest(0, k, k, {c: DISK for c in range(4)})
    newk = rng.randn(2, 8).astype(np.float16)
    st_.append_token(0, 63, newk, newk)          # dirties chunk 3
    assert not st_._sidecar_valid[0, 0, 3]
    assert st_.requant_sweep() == 0              # round r: just appended
    assert st_.requant_sweep() == 1              # round r+1: quiet -> repack
    assert bool(st_._sidecar_valid[0, 0, 3])
    assert st_.sidecar_repacks == 1
    packed = st_.chunk_bytes * compression.codec_ratio("int4", group=16)
    assert st_.log.total(kind="sidecar_repack") == pytest.approx(packed)
    # promotion reads packed bytes again and the appended row round-trips
    st_.demote(0, [3], to=DISK)
    _, _, fst = st_.fetch_chunks_pooled(0, {0: [3]})
    assert fst.disk_bytes == pytest.approx(packed)
    got = st_._host_k[(0, 0, 3)][15].astype(np.float32)
    # symmetric int4 round-trip: error bounded by half the per-channel
    # scale of the REPACKED chunk (which includes the appended row)
    chunk3 = np.array(st_._disk[0, 0, 3, 0])
    _, scale = compression.quantize_chunks(chunk3[None], "int4")
    bound = scale[0].reshape(2, 8) / 2 + 2e-3
    assert np.all(np.abs(got - newk.astype(np.float32)) <= bound)
    # tail chunk appended every round keeps its pending entry fresh
    for pos in (64, 65, 66):
        st_.append_token(0, pos, newk, newk)
        st_.requant_sweep()
    assert not st_._sidecar_valid[0, 0, 4]
    st_.close()


def test_requant_sweep_engine_smoke():
    """Live engine with disk_sidecar: decode rounds trigger background
    repacks through the shared prefetch executor (counted), and the token
    stream is unchanged vs sidecar_requant=False."""
    cfg, _ = _setup()
    prompt = np.random.RandomState(3).randint(2, cfg.vocab_size, 60)
    streams = {}
    repacks = {}
    for sweep in (False, True):
        eng = _engine(disk_sidecar=True, real_codec=True,
                      sidecar_requant=sweep)
        streams[sweep] = _gen(eng, prompt, n_new=6)
        eng.store.requant_fence()
        repacks[sweep] = eng.store.sidecar_repacks
        eng.store.close()
    assert streams[True] == streams[False]
    assert repacks[False] == 0
    assert repacks[True] > 0


# ---------------------------------------------------------------------------
# Analytic model
# ---------------------------------------------------------------------------


def test_chunked_admission_model_bounds_round_gap():
    m = chunked_admission_model(chunk_s=0.1, n_chunks=8, round_s=0.2,
                                chunks_per_round=2)
    assert m["max_round_gap_chunked_s"] == pytest.approx(0.2 + 2 * 0.1)
    assert m["max_round_gap_whole_s"] == pytest.approx(0.2 + 8 * 0.1)
    assert m["ttft_whole_s"] == pytest.approx(0.8)
    # chunked TTFT pays exactly the interleaved rounds
    assert m["ttft_chunked_s"] == pytest.approx(0.8 + 3 * 0.2)
    # a budget >= the whole prompt degenerates to whole-prompt admission
    m1 = chunked_admission_model(0.1, 8, 0.2, 8)
    assert m1["ttft_chunked_s"] == pytest.approx(m1["ttft_whole_s"])
    assert m1["max_round_gap_chunked_s"] == \
        pytest.approx(m1["max_round_gap_whole_s"])
