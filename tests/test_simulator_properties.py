"""Property tests over the latency simulator (physics invariants)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.serving.simulator import (HWCfg, ServeCfg, compare_policies,
                                     simulate_decode, simulate_request)

CFG = get_config("longchat-7b-32k")


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.sampled_from([2048, 8192, 16384]))
def test_leoam_never_slower_than_baselines(batch, prompt):
    res = compare_policies(CFG, ServeCfg(batch=batch, prompt=prompt,
                                         output=32))
    assert res["leoam_all"]["total_s"] <= res["h2o"]["total_s"] + 1e-9
    assert res["leoam_all"]["total_s"] <= res["full"]["total_s"] + 1e-9


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([2048, 8192, 32768]))
def test_latency_monotone_in_context(prompt):
    a = simulate_request(CFG, ServeCfg(batch=2, prompt=prompt, output=32),
                         HWCfg(), "leoam_all")
    b = simulate_request(CFG, ServeCfg(batch=2, prompt=prompt * 2, output=32),
                         HWCfg(), "leoam_all")
    assert b["total_s"] >= a["total_s"]


@settings(max_examples=10, deadline=None)
@given(st.floats(0.02, 0.5))
def test_decode_cost_monotone_in_budget(rate):
    lo = simulate_decode(CFG, ServeCfg(batch=2, prompt=8192,
                                       importance_rate=rate), HWCfg(),
                         "leoam_all")
    hi = simulate_decode(CFG, ServeCfg(batch=2, prompt=8192,
                                       importance_rate=min(1.0, rate * 2)),
                         HWCfg(), "leoam_all")
    assert hi.total_s >= lo.total_s - 1e-9


def test_faster_disk_helps_baseline_more():
    """LeoAM's advantage shrinks as the disk gets faster (its whole point
    is hiding disk bandwidth)."""
    slow = compare_policies(CFG, ServeCfg(batch=4, prompt=8192, output=64),
                            HWCfg(disk_bw=3e9))
    fast = compare_policies(CFG, ServeCfg(batch=4, prompt=8192, output=64),
                            HWCfg(disk_bw=30e9))
    adv_slow = slow["h2o"]["total_s"] / slow["leoam_all"]["total_s"]
    adv_fast = fast["h2o"]["total_s"] / fast["leoam_all"]["total_s"]
    assert adv_slow > adv_fast
