"""Train a ~100M-parameter LM for a few hundred steps on the synthetic
corpus (deliverable-(b) end-to-end driver), with checkpoint/restart.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import RuntimeCfg
from repro.data.synthetic import DataCfg, ShardedLoader
from repro.launch import steps as stp
from repro.models import lm
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # a ~100M-param qwen3-family config (d=512, 8 layers, vocab 32k)
    base = get_config("qwen3-1.7b")
    cfg = dataclasses.replace(
        base, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32_768,
        dtype="float32", prologue_layers=2,
        runtime=RuntimeCfg(microbatches=1, remat="none"),
        leoam=dataclasses.replace(base.leoam, enabled=False))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n / 1e6:.1f}M")

    tcfg = stp.TrainCfg(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    state = {"params": params, "opt": adamw.init_opt_state(params, tcfg.adam)}
    step = jax.jit(stp.make_train_step(cfg, tcfg))
    loader = ShardedLoader(DataCfg(vocab_size=cfg.vocab_size, seq_len=256,
                                   global_batch=16))
    ck = Checkpointer(args.ckpt, keep=2)

    t0, losses = time.perf_counter(), []
    for i in range(args.steps):
        batch = next(loader)
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if i % 20 == 0 or i == args.steps - 1:
            losses.append(float(m["loss"]))
            tput = (i + 1) * 16 * 256 / (time.perf_counter() - t0)
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"acc={float(m['accuracy']):.3f} tok/s={tput:,.0f}")
        if i and i % 100 == 0:
            ck.save(i, state)
    ck.save(args.steps, state, block=True)
    loader.close()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(improved {losses[0] - losses[-1]:.3f} nats)")
    if args.steps >= 200:          # shorter runs are smoke-only
        assert losses[-1] < losses[0] - 0.5, "training did not learn"


if __name__ == "__main__":
    main()
