"""Visualize IAKM's tree-structured chunk management (paper Fig. 10) on a
synthetic attention pattern: deserts merge, islands split.

    PYTHONPATH=src python examples/adaptive_chunks_demo.py
"""

import numpy as np

from repro.core.adaptive import flat_chunk_select, tree_select
from repro.core.desert import desert_rate, optimal_chunk_size


def main() -> None:
    rng = np.random.RandomState(3)
    n, chunk, budget = 512, 32, 48
    scores = np.abs(rng.randn(n)) * 0.02
    for c in (40, 200, 330):                      # three attention islands
        w = rng.randint(12, 30)
        scores[c:c + w] += np.abs(rng.randn(w)) * 2 + 1
    scores += rng.rand(n) * 1e-9

    res = tree_select(scores, budget, chunk)
    flat = flat_chunk_select(scores, budget, chunk)

    print(f"{n} tokens, initial chunks of {chunk}, budget {budget}")
    print(f"desert rate (chunk {chunk}): "
          f"{desert_rate(scores, chunk, budget / n):.2f}")
    print(f"token-level evaluations: {n}")
    print(f"fixed-chunk evaluations: {flat.evaluations} "
          f"(useful transfer {flat.transfer_ratio:.2f})")
    print(f"LeoAM tree evaluations:  {res.evaluations} "
          f"(useful transfer {res.transfer_ratio:.2f})")
    print("\nfinal adaptive partition (column per segment; #=important):")
    line, ruler = [], []
    for lo, hi, imp in res.partition:
        width = max(1, (hi - lo) // 8)
        line.append(("#" if imp else ".") * width)
        ruler.append(f"{lo}".ljust(width))
    print("".join(line))
    print("".join(ruler)[:120])
    print(f"\nEq.(2) optimal chunk size: dense layer (rho=0.5) -> "
          f"{optimal_chunk_size(n, 0.5)}, sparse layer (rho=0.08) -> "
          f"{optimal_chunk_size(n, 0.08)}")


if __name__ == "__main__":
    main()
