"""End-to-end LeoAM serving: three-tier KV offloading with live traffic
audit — the paper's system running for real on this machine.

    PYTHONPATH=src python examples/serve_longcontext.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import EngineCfg, LeoAMEngine
from repro.serving.simulator import ServeCfg, compare_policies


def main() -> None:
    # --- live engine on a smoke model -----------------------------------
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.2,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = LeoAMEngine(cfg, params, EngineCfg(max_len=512, gpu_chunk_frac=0.1,
                                             cpu_chunk_frac=0.4,
                                             selection="tree"))
    prompt = np.random.RandomState(0).randint(2, cfg.vocab_size, 300)
    t0 = time.perf_counter()
    toks = eng.generate(prompt, 12)
    print(f"[engine] 12 tokens in {time.perf_counter() - t0:.2f}s: {toks}")
    for (src, dst, kind), b in sorted(eng.store.log.bytes.items()):
        print(f"[engine]   {src:>6s}->{dst:6s} {kind:10s} {b / 2**20:7.3f} MiB")
    eng.store.close()

    # --- paper-testbed latency model (RTX-4090 + PCIe4 + 7GB/s SSD) ------
    full = get_config("longchat-7b-32k")
    res = compare_policies(full, ServeCfg(batch=4, prompt=8192, output=128))
    base = min(res[p]["total_s"] for p in ("h2o", "h2o_chunked", "prefetch"))
    print("\n[simulator] 8k prompt, 128 new tokens, batch 4:")
    for p, r in res.items():
        print(f"[simulator]   {p:12s} {r['total_s']:7.1f}s "
              f"({base / r['total_s']:.2f}x vs best baseline)")


if __name__ == "__main__":
    main()
