"""Quickstart: LeoAM sparse decode on a small model, end to end.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API surface: config registry → model init → prefill →
LeoAM decode (abstract pyramid + adaptive selection) vs dense decode, and
how close the budgeted output stays to the full-cache output.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm


def main() -> None:
    # 1. pick an architecture (any of the ten assigned ids works)
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.15,
                                       min_seq_for_sparse=64))
    params = lm.init(cfg, jax.random.PRNGKey(0))

    # 2. prefill a prompt; the cache carries KV + the LKA abstract pyramid
    rng = np.random.RandomState(0)
    prompt = jnp.asarray(rng.randint(2, cfg.vocab_size, (1, 255)), jnp.int32)
    logits, cache = lm.prefill(params, cfg, {"tokens": prompt}, max_len=256)
    tok = int(jnp.argmax(logits[0]))
    print(f"prefill done; first token {tok}")
    print("cache leaves:", sorted(cache["prologue"][0].keys()))

    # 3. decode with LeoAM adaptive selection (15% budget + sink/recent)
    logits_sparse, _ = lm.decode_step(params, cfg, cache,
                                      {"token": jnp.asarray([tok])},
                                      jnp.int32(255))

    # 4. compare against dense decode (full cache attended)
    dense = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, min_seq_for_sparse=10**9))
    logits_dense, _ = lm.decode_step(params, dense, cache,
                                     {"token": jnp.asarray([tok])},
                                     jnp.int32(255))
    a, b = np.asarray(logits_sparse[0]), np.asarray(logits_dense[0])
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    print(f"sparse-vs-dense logits: cos={cos:.4f} "
          f"argmax_agree={a.argmax() == b.argmax()}")
    print("note: random-init attention is near-uniform (the technique's "
          "worst case); on attention-concentrated caches the same budget "
          "gives <1% error — see tests/test_sparse_attention.py")


if __name__ == "__main__":
    main()
